"""import-direction, hotpath-jax, and rng-stream.

**import-direction** — the PR-4 seam: ``protocol/`` is the
transport-agnostic lease/handout layer and must stay importable without
pulling in the simulator or the baseline schemes (``core.simulator``,
``core.baselines``); ``transfer/`` is the wire layer underneath both
and must not import ``protocol`` at all.  One inverted import and
vc_serve's cold-start drags the whole simulator in.

**hotpath-jax** — the fleet hot path (``run_simulation``'s event loop
and its nested per-event handlers; the ``*_flat`` scenario methods)
processes millions of events; a single ``jax.*`` call per event is a
dispatch + potential trace per event, the exact regression the
events-per-sec gate exists to catch.  JAX setup BEFORE the loop is
fine; numpy inside it is fine.

**rng-stream** — reproducibility of the pinned sim cases requires every
draw to come from a named ``np.random.default_rng``/``Generator``
stream (or an explicit ``jax.random`` key).  Module-level
``np.random.<sampler>`` and stdlib ``random.*`` calls share hidden
global state across scenarios and break replay.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Set, Tuple

from repro.analysis.framework import (FileContext, Rule, Violation,
                                      call_name, dotted, register)


# ---------------------------------------------------------------------------
# import-direction
# ---------------------------------------------------------------------------

def _imported_modules(tree: ast.AST) -> List[Tuple[ast.stmt, str]]:
    """(node, dotted-module) for every import, with ImportFrom names
    appended so ``from repro.core import simulator`` yields
    ``repro.core.simulator``."""
    out: List[Tuple[ast.stmt, str]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out.append((node, alias.name))
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            out.append((node, base))
            for alias in node.names:
                out.append((node, f"{base}.{alias.name}" if base
                            else alias.name))
    return out


@register
class ImportDirectionRule(Rule):
    name = "import-direction"
    doc = ("protocol/ must not import core.simulator or core.baselines; "
           "transfer/ must not import protocol")

    def wants(self, ctx: FileContext) -> bool:
        return ctx.under("protocol") or ctx.under("transfer")

    def check(self, ctx: FileContext) -> Iterable[Violation]:
        out: List[Violation] = []
        mods = _imported_modules(ctx.tree)
        if ctx.under("protocol"):
            for node, mod in mods:
                for banned in ("core.simulator", "core.baselines"):
                    if mod == banned or mod.endswith("." + banned) \
                            or (mod + ".").find(banned + ".") >= 0:
                        out.append(ctx.violation(
                            "import-direction", node,
                            f"protocol/ imports `{mod}` — the lease "
                            f"layer must stay importable without the "
                            f"simulator/baselines (PR-4 seam)"))
                        break
        if ctx.under("transfer"):
            for node, mod in mods:
                parts = mod.split(".")
                if "protocol" in parts:
                    out.append(ctx.violation(
                        "import-direction", node,
                        f"transfer/ imports `{mod}` — the wire layer "
                        f"sits below protocol/ and must not depend on "
                        f"it"))
        # dedupe (ImportFrom emits base + expanded names)
        seen: Set[tuple] = set()
        uniq = []
        for v in out:
            k = (v.path, v.line, v.rule)
            if k not in seen:
                seen.add(k)
                uniq.append(v)
        return uniq


# ---------------------------------------------------------------------------
# hotpath-jax
# ---------------------------------------------------------------------------

def _jax_refs(node: ast.AST) -> Iterable[ast.AST]:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id in ("jax", "jnp"):
            yield n
        elif isinstance(n, ast.Attribute):
            root = dotted(n).split(".", 1)[0]
            if root in ("jax", "jnp"):
                yield n


@register
class HotpathJaxRule(Rule):
    name = "hotpath-jax"
    doc = ("no per-event jax.*/jnp.* in core/simulator.py's event loop "
           "or nested handlers, nor in scenarios/ *_flat methods")

    def wants(self, ctx: FileContext) -> bool:
        return ctx.endswith("core/simulator.py") or ctx.under("scenarios")

    def check(self, ctx: FileContext) -> Iterable[Violation]:
        out: List[Violation] = []
        if ctx.endswith("core/simulator.py"):
            self._check_simulator(ctx, out)
        if ctx.under("scenarios"):
            self._check_flat_methods(ctx, out)
        return out

    @staticmethod
    def _check_simulator(ctx: FileContext, out: List[Violation]) -> None:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, ast.FunctionDef) \
                    or fn.name != "run_simulation":
                continue
            hot: List[ast.AST] = []
            for stmt in ast.walk(fn):
                if isinstance(stmt, ast.While):
                    hot.append(stmt)              # the event loop itself
                elif isinstance(stmt, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)) \
                        and stmt is not fn:
                    hot.append(stmt)              # per-event handlers
            seen: Set[int] = set()
            for region in hot:
                for ref in _jax_refs(region):
                    line = getattr(ref, "lineno", 0)
                    if line in seen:
                        continue
                    seen.add(line)
                    out.append(ctx.violation(
                        "hotpath-jax", ref,
                        f"`{dotted(ref) or 'jax'}` inside "
                        f"run_simulation's event loop / handler — one "
                        f"dispatch per event; hoist it out of the loop "
                        f"(numpy is fine here)"))

    @staticmethod
    def _check_flat_methods(ctx: FileContext, out: List[Violation]) -> None:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not fn.name.endswith("_flat"):
                continue
            seen: Set[int] = set()
            for ref in _jax_refs(fn):
                line = getattr(ref, "lineno", 0)
                if line in seen:
                    continue
                seen.add(line)
                out.append(ctx.violation(
                    "hotpath-jax", ref,
                    f"`{dotted(ref) or 'jax'}` in flat-path "
                    f"`{fn.name}` — flat scenario methods run per "
                    f"client-event and must stay numpy-only"))


# ---------------------------------------------------------------------------
# rng-stream
# ---------------------------------------------------------------------------

_NP_RANDOM_OK = frozenset({"default_rng", "Generator", "SeedSequence",
                           "PCG64", "Philox", "BitGenerator"})


@register
class RngStreamRule(Rule):
    name = "rng-stream"
    doc = ("simulator/scenarios must draw from named np.random "
           "Generator streams (or explicit jax.random keys), never "
           "module-level random state")

    def wants(self, ctx: FileContext) -> bool:
        return (ctx.endswith("core/simulator.py") or ctx.under("scenarios")) \
            and ("random" in ctx.source)

    def check(self, ctx: FileContext) -> Iterable[Violation]:
        out: List[Violation] = []
        for call in ast.walk(ctx.tree):
            if not isinstance(call, ast.Call):
                continue
            name = call_name(call)
            parts = name.split(".")
            if len(parts) >= 3 and parts[-3] == "np" \
                    and parts[-2] == "random" \
                    and parts[-1] not in _NP_RANDOM_OK:
                out.append(ctx.violation(
                    "rng-stream", call,
                    f"`{name}()` draws from numpy's hidden global "
                    f"stream — use a named `np.random.default_rng(seed)` "
                    f"generator so pinned sim cases replay"))
            elif len(parts) == 2 and parts[0] == "random":
                out.append(ctx.violation(
                    "rng-stream", call,
                    f"stdlib `{name}()` uses module-level state — use a "
                    f"named np.random Generator stream"))
            elif name == "np.random.seed" or (
                    len(parts) >= 2 and parts[-2] == "random"
                    and parts[-1] == "seed" and parts[0] != "jax"):
                out.append(ctx.violation(
                    "rng-stream", call,
                    f"`{name}()` reseeds global state — construct a "
                    f"fresh named Generator instead"))
        return out
