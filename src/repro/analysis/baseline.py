"""Baseline ratchet for vclint.

``results/BASELINE_vclint.json`` pins the per-rule violation counts the
repo is allowed to carry.  The ratchet is monotone: a run whose count
for any rule EXCEEDS the baseline fails (exit 1); a run that comes in
under it passes but reports the slack so the baseline can be re-pinned
with ``--update-baseline`` (counts may only shrink — the tool refuses
to write a baseline that grows a rule's count without ``--force``
semantics, which deliberately do not exist: fix the code instead).
A missing baseline is exit 2, so CI distinguishes "regressed" from
"never pinned".
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.analysis.framework import Report

BASELINE_SCHEMA_VERSION = 1
DEFAULT_BASELINE = Path("results") / "BASELINE_vclint.json"

EXIT_CLEAN = 0
EXIT_VIOLATIONS = 1
EXIT_NO_BASELINE = 2


def load_baseline(path: Path) -> Optional[Dict]:
    path = Path(path)
    if not path.is_file():
        return None
    data = json.loads(path.read_text())
    data.setdefault("by_rule", {})
    return data


def write_baseline(path: Path, report: Report) -> Dict:
    path = Path(path)
    prev = load_baseline(path)
    if prev is not None:
        grew = {r: (prev["by_rule"].get(r, 0), n)
                for r, n in report.by_rule.items()
                if n > prev["by_rule"].get(r, 0)}
        if grew:
            detail = ", ".join(f"{r}: {a}->{b}"
                               for r, (a, b) in sorted(grew.items()))
            raise SystemExit(
                f"vclint: refusing to re-pin a LARGER baseline "
                f"({detail}); fix the violations instead")
    data = {
        "schema_version": BASELINE_SCHEMA_VERSION,
        "total": report.total,
        "by_rule": report.by_rule,
    }
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
    return data


def check_ratchet(report: Report,
                  baseline: Optional[Dict]) -> Tuple[int, List[str]]:
    """(exit_code, messages) for a report against a loaded baseline."""
    if baseline is None:
        return EXIT_NO_BASELINE, [
            "vclint: no baseline (results/BASELINE_vclint.json); run "
            "with --update-baseline to pin one"]
    msgs: List[str] = []
    code = EXIT_CLEAN
    pinned = baseline.get("by_rule", {})
    for rule, count in sorted(report.by_rule.items()):
        allowed = pinned.get(rule, 0)
        if count > allowed:
            code = EXIT_VIOLATIONS
            msgs.append(f"vclint: {rule}: {count} > baseline {allowed} "
                        f"(new violations; fix them — the ratchet only "
                        f"shrinks)")
    for rule, allowed in sorted(pinned.items()):
        count = report.by_rule.get(rule, 0)
        if count < allowed:
            msgs.append(f"vclint: {rule}: {count} < baseline {allowed} "
                        f"(improved; re-pin with --update-baseline)")
    if code == EXIT_CLEAN and not msgs:
        msgs.append("vclint: clean against baseline")
    return code, msgs
