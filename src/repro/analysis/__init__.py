"""vclint — repo-native static analysis for the VC training stack.

Entry points: :func:`repro.analysis.framework.lint_paths` (library),
``python -m tools.vclint`` (CLI), ``tests/test_vclint.py`` (tier-1
ratchet).  See docs/LINT.md for the rule catalog.
"""
from repro.analysis.framework import (Report, Rule, Violation,  # noqa: F401
                                      all_rules, lint_paths)
