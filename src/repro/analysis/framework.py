"""vclint core: rule registry, per-file AST dispatch, suppressions.

Nine PRs of protocol/wire/kernel invariants (docs/PROTOCOL.md,
docs/ROOFLINE.md, CHANGES.md) were enforced only *dynamically* — by
pinned regressions and property tests that fire after a bug is already
written.  This package promotes them to a static tier that runs at parse
time, before a single test: each :class:`Rule` encodes one repo-native
invariant as an AST check, the runner dispatches every linted file
through every applicable rule exactly once, and the committed baseline
(results/BASELINE_vclint.json, see ``baseline.py``) ratchets the
violation count monotonically toward zero.

Suppressions: ``# vclint: disable=rule-a,rule-b`` as a trailing comment
suppresses those rules on that line; as a standalone comment line it
suppresses them on the comment line AND the next source line.  Every
suppression must actually suppress something — a disable comment that
matched no violation is itself reported as ``unused-suppression`` (so
stale waivers can't rot in place).
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Type

_SUPPRESS_RE = re.compile(r"#\s*vclint:\s*disable=([A-Za-z0-9_,\s-]+)")

# rules the framework itself emits (not in the registry)
META_RULES = ("parse-error", "unused-suppression")


@dataclass(frozen=True, order=True)
class Violation:
    """One finding: ``path`` is repo-root-relative (posix)."""

    path: str
    line: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class FileContext:
    """Everything a rule may inspect about one file, parsed once."""

    def __init__(self, path: Path, relpath: str, source: str,
                 repo_root: Path):
        self.path = path
        self.relpath = relpath                  # posix, repo-root-relative
        self.repo_root = repo_root
        self.source = source
        self.lines = source.splitlines()
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[SyntaxError] = None
        try:
            self.tree = ast.parse(source, filename=relpath)
        except SyntaxError as e:                # reported as parse-error
            self.parse_error = e

    # -- path helpers rules key off --------------------------------------
    def endswith(self, *suffixes: str) -> bool:
        """True iff relpath ends with one of ``suffixes`` at a path-part
        boundary (``core/simulator.py`` matches ``src/repro/core/...``
        but never ``hardcore/simulator.py``)."""
        for s in suffixes:
            if self.relpath == s or self.relpath.endswith("/" + s):
                return True
        return False

    def under(self, *dirs: str) -> bool:
        """True iff some path component sequence matches ``dirs`` (e.g.
        ``under('protocol')`` for any file in a protocol/ directory)."""
        parts = self.relpath.split("/")
        for d in dirs:
            want = d.split("/")
            n = len(want)
            if any(parts[i:i + n] == want
                   for i in range(len(parts) - n)):
                return True
        return False

    def violation(self, rule: str, node, message: str) -> Violation:
        line = getattr(node, "lineno", node if isinstance(node, int) else 0)
        return Violation(path=self.relpath, line=int(line), rule=rule,
                        message=message)


class Rule:
    """One invariant.  Subclasses set ``name``/``doc``, override
    ``wants`` to scope themselves to the files the invariant lives in,
    and yield :class:`Violation` from ``check``."""

    name: str = ""
    doc: str = ""

    def wants(self, ctx: FileContext) -> bool:
        return True

    def check(self, ctx: FileContext) -> Iterable[Violation]:
        raise NotImplementedError


_REGISTRY: Dict[str, Rule] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    """Class decorator: instantiate and register a rule by its name."""
    rule = cls()
    if not rule.name:
        raise ValueError(f"rule {cls.__name__} has no name")
    if rule.name in _REGISTRY:
        raise ValueError(f"duplicate rule name {rule.name!r}")
    _REGISTRY[rule.name] = rule
    return cls


def all_rules() -> Dict[str, Rule]:
    """The registry (rule modules are imported for their side effect)."""
    from repro.analysis import rules as _rules  # noqa: F401  (registers)
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

class _Suppressions:
    """Per-file map of line -> suppressed rule names, with usage
    tracking for unused-suppression detection."""

    def __init__(self, ctx: FileContext):
        self.by_line: Dict[int, Set[str]] = {}
        # comment line -> (rules, lines it covers) for usage reporting
        self.sites: List[tuple] = []
        for i, text in self._comments(ctx.source):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            line = ctx.lines[i - 1] if i <= len(ctx.lines) else text
            covered = [i]
            if line.strip().startswith("#"):
                covered.append(i + 1)       # standalone: covers next line
            for ln in covered:
                self.by_line.setdefault(ln, set()).update(rules)
            self.sites.append((i, rules, covered, set()))

    @staticmethod
    def _comments(source: str) -> List[tuple]:
        """(lineno, text) of REAL comment tokens only — a disable
        example quoted inside a docstring is not a suppression."""
        out: List[tuple] = []
        try:
            for tok in tokenize.generate_tokens(
                    io.StringIO(source).readline):
                if tok.type == tokenize.COMMENT:
                    out.append((tok.start[0], tok.string))
        except (tokenize.TokenizeError, IndentationError, SyntaxError):
            pass                            # parse-error path reports it
        return out

    def filter(self, violations: List[Violation]) -> List[Violation]:
        kept = []
        for v in violations:
            sup = self.by_line.get(v.line, ())
            if v.rule in sup:
                for (_, rules, covered, used) in self.sites:
                    if v.line in covered and v.rule in rules:
                        used.add(v.rule)
                continue
            kept.append(v)
        return kept

    def unused(self, ctx: FileContext) -> List[Violation]:
        out = []
        for (line, rules, _, used) in self.sites:
            for r in sorted(rules - used):
                out.append(ctx.violation(
                    "unused-suppression", line,
                    f"suppression for {r!r} matched no violation "
                    f"(remove it, or the rule name is wrong)"))
        return out


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

@dataclass
class Report:
    violations: List[Violation]
    files_checked: int
    rules_run: List[str]

    @property
    def by_rule(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for v in self.violations:
            counts[v.rule] = counts.get(v.rule, 0) + 1
        return dict(sorted(counts.items()))

    @property
    def total(self) -> int:
        return len(self.violations)


def iter_py_files(paths: Sequence[Path]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    # dedupe, stable order
    seen: Set[Path] = set()
    out = []
    for f in files:
        r = f.resolve()
        if r not in seen:
            seen.add(r)
            out.append(f)
    return out


def lint_paths(paths: Sequence[Path], *, repo_root: Path,
               rules: Optional[Dict[str, Rule]] = None) -> Report:
    """Lint every .py under ``paths``.  ``repo_root`` anchors the
    relative paths in violations and lets cross-file rules (e.g.
    kernel-triangle) find tests/ and sibling modules."""
    repo_root = Path(repo_root).resolve()
    active = rules if rules is not None else all_rules()
    violations: List[Violation] = []
    files = iter_py_files(paths)
    for f in files:
        fr = f.resolve()
        try:
            rel = fr.relative_to(repo_root).as_posix()
        except ValueError:
            rel = fr.as_posix()
        try:
            source = f.read_text()
        except (OSError, UnicodeDecodeError) as e:
            violations.append(Violation(rel, 0, "parse-error",
                                        f"unreadable: {e}"))
            continue
        ctx = FileContext(f, rel, source, repo_root)
        if ctx.parse_error is not None:
            violations.append(ctx.violation(
                "parse-error", ctx.parse_error.lineno or 0,
                f"syntax error: {ctx.parse_error.msg}"))
            continue
        raw: List[Violation] = []
        for rule in active.values():
            if rule.wants(ctx):
                raw.extend(rule.check(ctx))
        sup = _Suppressions(ctx)
        violations.extend(sup.filter(raw))
        violations.extend(sup.unused(ctx))
    violations.sort()
    return Report(violations=violations, files_checked=len(files),
                  rules_run=sorted(active))


# ---------------------------------------------------------------------------
# small AST helpers shared by rules
# ---------------------------------------------------------------------------

def dotted(node: ast.AST) -> str:
    """``a.b.c`` for Name/Attribute chains, '' for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_name(call: ast.Call) -> str:
    return dotted(call.func)


def walk_calls(node: ast.AST) -> Iterable[ast.Call]:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            yield n
