from repro.checkpoint.store import (CheckpointManager, load_checkpoint,
                                    save_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]
