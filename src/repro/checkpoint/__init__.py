from repro.checkpoint.store import (CheckpointManager, load_checkpoint,
                                    load_flat_checkpoint,
                                    load_train_checkpoint, save_checkpoint,
                                    save_flat_checkpoint,
                                    save_train_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager",
           "save_flat_checkpoint", "load_flat_checkpoint",
           "save_train_checkpoint", "load_train_checkpoint"]
