"""Checkpointing: msgpack tensor store with atomic rename, async save,
retention, and restart logic.

This is the durability layer of the VC design: the *server copy* is the
only state that must survive (clients/islands are disposable by design —
the paper's whole point), so checkpoints are snapshots of
(server params, opt state, round counter, alpha-schedule position, data
cursor).  ``CheckpointManager.restore_or_init`` is what every launcher
calls first: a preempted coordinator resumes exactly where the last
assimilation left off.

Server state on the FlatParams bus (core/flat.py) takes the flat path:
``save_flat_checkpoint`` writes the TreeSpec offset table in the header
and the parameter set as ONE contiguous buffer (no leaf-by-leaf packing);
the manager routes FlatParams there automatically.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _tree_to_payload(tree) -> Tuple[Dict, list]:
    leaves, treedef = jax.tree.flatten(tree)
    metas, bufs = [], []
    for leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            metas.append({"dtype": "bfloat16", "shape": arr.shape})
            bufs.append(arr.view(np.uint16).tobytes())
        else:
            metas.append({"dtype": str(arr.dtype), "shape": arr.shape})
            bufs.append(arr.tobytes())
    return {"treedef": str(treedef), "metas": metas}, bufs


def save_checkpoint(path: str | Path, tree, extra: Optional[Dict] = None
                    ) -> None:
    """Atomic save: write to a temp file in the same dir, then rename."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    header, bufs = _tree_to_payload(tree)
    header["extra"] = extra or {}
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(header, use_bin_type=True))
            for b in bufs:
                f.write(msgpack.packb(b, use_bin_type=True))
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_checkpoint(path: str | Path, tree_like) -> Tuple[Any, Dict]:
    """Restore into the structure of `tree_like` (shapes must match)."""
    path = Path(path)
    leaves, treedef = jax.tree.flatten(tree_like)
    with open(path, "rb") as f:
        unpacker = msgpack.Unpacker(f, raw=False, max_buffer_size=2 ** 31)
        header = next(unpacker)
        out = []
        for meta, like in zip(header["metas"], leaves):
            buf = next(unpacker)
            if meta["dtype"] == "bfloat16":
                arr = np.frombuffer(buf, np.uint16).reshape(meta["shape"])
                arr = jnp.asarray(arr.view(jnp.bfloat16))
            else:
                arr = jnp.asarray(np.frombuffer(
                    buf, np.dtype(meta["dtype"])).reshape(meta["shape"]))
            out.append(arr)
    return jax.tree.unflatten(treedef, out), header.get("extra", {})


# ---------------------------------------------------------------------------
# flat-bus checkpoints (core/flat.py): ONE contiguous buffer write instead
# of leaf-by-leaf packing.  The TreeSpec offset table rides in the header;
# the treedef itself (not serializable) is re-derived from `tree_like` at
# load, exactly like load_checkpoint.
# ---------------------------------------------------------------------------

def save_flat_checkpoint(path: str | Path, fp, extra: Optional[Dict] = None
                         ) -> None:
    """Atomic save of a FlatParams: header (layout + extra) + one buffer."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    buf_dtype, raw = _buf_to_bytes(np.asarray(jax.device_get(fp.buf)))
    header = {"flat": fp.spec.meta(), "buf_dtype": buf_dtype,
              "treedef": str(fp.spec.treedef), "extra": extra or {}}
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(header, use_bin_type=True))
            f.write(msgpack.packb(raw, use_bin_type=True))
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_flat_checkpoint(path: str | Path, like) -> Tuple[Any, Dict]:
    """Restore a FlatParams saved by save_flat_checkpoint.

    ``like`` supplies the treedef: a FlatParams, a TreeSpec, or a template
    tree with the same structure.  The stored offset table is validated
    against it (shape/offset mismatch -> ValueError, not silent garbage)."""
    from repro.core import flat as F
    path = Path(path)
    spec = _spec_of(like)
    with open(path, "rb") as f:
        unpacker = msgpack.Unpacker(f, raw=False, max_buffer_size=2 ** 31)
        header = next(unpacker)
        raw = next(unpacker)
    if header.get("kind") == "flat-train":
        raise ValueError(f"{path} is a train checkpoint (params+m+v); "
                         f"use load_train_checkpoint")
    _check_layout(header["flat"], spec, path)
    buf = _buf_from_bytes(raw, header["buf_dtype"])
    return F.FlatParams(buf, spec), header.get("extra", {})


def _spec_of(like):
    from repro.core import flat as F
    if isinstance(like, F.FlatParams):
        return like.spec
    if isinstance(like, F.TreeSpec):
        return like
    return F.tree_spec(like)


def _check_layout(meta: Dict, spec, path) -> None:
    # sharded layouts (ShardedTreeSpec) pin the segment geometry: a record
    # written n_shards-way only restores onto the same partitioning.
    # Checked FIRST so the error names the shard mismatch (the padded
    # length usually differs too, which the generic check would mask).
    from repro.core import flat as F
    want = None
    if isinstance(spec, F.ShardedTreeSpec):
        want = {"n_shards": spec.n_shards, "shard_len": spec.shard_len,
                "axis": spec.axis}
    have = meta.get("shard")
    if want != have:
        raise ValueError(
            f"flat checkpoint shard-layout mismatch: record {have} vs "
            f"requested {want}: {path}")
    if (tuple(tuple(s) for s in meta["shapes"]) != spec.shapes
            or tuple(meta["offsets"]) != spec.offsets
            or meta["n"] != spec.n or meta["padded"] != spec.padded):
        raise ValueError(f"flat checkpoint layout mismatch: {path}")


def _buf_from_bytes(raw: bytes, dtype_name: str) -> jnp.ndarray:
    if dtype_name == "bfloat16":
        return jnp.asarray(np.frombuffer(raw, np.uint16).view(jnp.bfloat16))
    return jnp.asarray(np.frombuffer(raw, np.dtype(dtype_name)))


def _buf_to_bytes(arr: np.ndarray) -> Tuple[str, bytes]:
    """Encode twin of _buf_from_bytes (bf16 rides as uint16 bits)."""
    if arr.dtype == jnp.bfloat16:
        return "bfloat16", arr.view(np.uint16).tobytes()
    return str(arr.dtype), arr.tobytes()


# ---------------------------------------------------------------------------
# one-pass TRAIN checkpoints: params + Adam m/v as THREE LANES OF ONE
# CONTIGUOUS RECORD.  The whole training state (params, m, v, step) is
# written with a single buffer write and restored atomically — the resume
# path after preemption (core/simulator.py::run_preemptible_training) is
# one read, zero leaf walks.
# ---------------------------------------------------------------------------

def save_train_checkpoint(path: str | Path, fp, opt,
                          extra: Optional[Dict] = None) -> None:
    """Atomic save of (FlatParams, FlatOptState): one header + ONE
    contiguous record laid out as [params | m | v]."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    if fp.spec.padded != opt.spec.padded or fp.spec.shapes != opt.spec.shapes:
        raise ValueError("params and optimizer state do not share a layout")
    p_dtype, p_raw = _buf_to_bytes(np.asarray(jax.device_get(fp.buf)))
    m_raw = np.asarray(jax.device_get(opt.m), np.float32).tobytes()
    v_raw = np.asarray(jax.device_get(opt.v), np.float32).tobytes()
    header = {"kind": "flat-train", "flat": fp.spec.meta(),
              "buf_dtype": p_dtype, "lane_bytes": [len(p_raw), len(m_raw),
                                                   len(v_raw)],
              "step": int(jax.device_get(opt.step)),
              "treedef": str(fp.spec.treedef), "extra": extra or {}}
    record = b"".join((p_raw, m_raw, v_raw))  # ONE contiguous record
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(header, use_bin_type=True))
            f.write(msgpack.packb(record, use_bin_type=True))
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_train_checkpoint(path: str | Path, like) -> Tuple[Any, Any, Dict]:
    """Restore (FlatParams, FlatOptState, extra) saved by
    save_train_checkpoint.  ``like`` supplies the layout exactly as in
    load_flat_checkpoint; the record is sliced into its three lanes by the
    header's byte offsets — no per-leaf unpacking."""
    from repro.core import flat as F
    path = Path(path)
    spec = _spec_of(like)
    with open(path, "rb") as f:
        unpacker = msgpack.Unpacker(f, raw=False, max_buffer_size=2 ** 31)
        header = next(unpacker)
        record = next(unpacker)
    if header.get("kind") != "flat-train":
        raise ValueError(f"{path} is not a train checkpoint; "
                         f"use load_flat_checkpoint")
    _check_layout(header["flat"], spec, path)
    lp, lm, lv = header["lane_bytes"]
    if len(record) != lp + lm + lv:
        raise ValueError(f"torn train checkpoint record: {path}")
    buf = _buf_from_bytes(record[:lp], header["buf_dtype"])
    m = jnp.asarray(np.frombuffer(record[lp:lp + lm], np.float32))
    v = jnp.asarray(np.frombuffer(record[lp + lm:], np.float32))
    opt = F.FlatOptState(m=m, v=v,
                         step=jnp.asarray(header["step"], jnp.int32),
                         spec=spec)
    return F.FlatParams(buf, spec), opt, header.get("extra", {})


class CheckpointManager:
    """Rolling checkpoints with async save and retention.

    save() snapshots on the calling thread's values but writes on a
    background thread (double-buffered — training never blocks on disk),
    mirroring how a real cluster writes to replicated object storage.
    """

    def __init__(self, directory: str | Path, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    def _path(self, step: int) -> Path:
        return self.dir / f"ckpt_{step:08d}.msgpack"

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        self.wait()
        from repro.core import flat as F
        flat = isinstance(tree, F.FlatParams)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            if flat:
                save_flat_checkpoint(self._path(step), host_tree, extra)
            else:
                save_checkpoint(self._path(step), host_tree, extra)
            self._gc()

        if self.async_save:
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()
        else:
            work()

    def save_train(self, step: int, fp, opt,
                   extra: Optional[Dict] = None) -> None:
        """One-pass (params + m + v) snapshot; same retention/async rules
        as save()."""
        self.wait()
        host_fp = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), fp)
        host_opt = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), opt)

        def work():
            save_train_checkpoint(self._path(step), host_fp, host_opt, extra)
            self._gc()

        if self.async_save:
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()
        else:
            work()

    def restore_train_or_init(self, like, init_fn):
        """Resume (params, opt state) from the newest train checkpoint or
        initialize fresh.  Returns ((fp, opt), extra, step)."""
        self.wait()
        step = self.latest_step()
        if step is None:
            return init_fn(), {}, 0
        fp, opt, extra = load_train_checkpoint(self._path(step), like)
        return (fp, opt), extra, step

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("ckpt_*.msgpack"))
        for old in ckpts[:-self.keep]:
            old.unlink(missing_ok=True)

    def latest_step(self) -> Optional[int]:
        ckpts = sorted(self.dir.glob("ckpt_*.msgpack"))
        if not ckpts:
            return None
        return int(ckpts[-1].stem.split("_")[1])

    # -- protocol server checkpoints (protocol/coordinator.py hooks) --------
    # the durable VC state is (server params, version): params ride the
    # one-pass flat path, the version counter rides the header.  Leases
    # and residuals are deliberately not persisted — in-flight work is
    # disposable by design and a restarted coordinator reissues it.

    def save_server(self, step: int, fp, version: int,
                    extra: Optional[Dict] = None) -> None:
        e = dict(extra or {})
        e["server_version"] = int(version)
        self.save(step, fp, e)

    def restore_server_or_init(self, like, init_fn):
        """Resume (params, version) from the newest checkpoint or init
        fresh.  Returns (params, version, extra, step) — ``extra`` is the
        caller-supplied dict save_server persisted alongside, so runtimes
        can resume their own counters (uids, round offsets)."""
        tree, extra, step = self.restore_or_init(like, init_fn)
        return tree, int(extra.get("server_version", 0)), extra, step

    def restore_or_init(self, tree_like, init_fn):
        """Resume from the newest checkpoint or initialize fresh.
        Returns (tree, extra, step)."""
        self.wait()
        from repro.core import flat as F
        step = self.latest_step()
        if step is None:
            return init_fn(), {}, 0
        if isinstance(tree_like, (F.FlatParams, F.TreeSpec)):
            tree, extra = load_flat_checkpoint(self._path(step), tree_like)
        else:
            tree, extra = load_checkpoint(self._path(step), tree_like)
        return tree, extra, step
