"""Checkpointing: msgpack tensor store with atomic rename, async save,
retention, and restart logic.

This is the durability layer of the VC design: the *server copy* is the
only state that must survive (clients/islands are disposable by design —
the paper's whole point), so checkpoints are snapshots of
(server params, opt state, round counter, alpha-schedule position, data
cursor).  ``CheckpointManager.restore_or_init`` is what every launcher
calls first: a preempted coordinator resumes exactly where the last
assimilation left off.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _tree_to_payload(tree) -> Tuple[Dict, list]:
    leaves, treedef = jax.tree.flatten(tree)
    metas, bufs = [], []
    for leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            metas.append({"dtype": "bfloat16", "shape": arr.shape})
            bufs.append(arr.view(np.uint16).tobytes())
        else:
            metas.append({"dtype": str(arr.dtype), "shape": arr.shape})
            bufs.append(arr.tobytes())
    return {"treedef": str(treedef), "metas": metas}, bufs


def save_checkpoint(path: str | Path, tree, extra: Optional[Dict] = None
                    ) -> None:
    """Atomic save: write to a temp file in the same dir, then rename."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    header, bufs = _tree_to_payload(tree)
    header["extra"] = extra or {}
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(header, use_bin_type=True))
            for b in bufs:
                f.write(msgpack.packb(b, use_bin_type=True))
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_checkpoint(path: str | Path, tree_like) -> Tuple[Any, Dict]:
    """Restore into the structure of `tree_like` (shapes must match)."""
    path = Path(path)
    leaves, treedef = jax.tree.flatten(tree_like)
    with open(path, "rb") as f:
        unpacker = msgpack.Unpacker(f, raw=False, max_buffer_size=2 ** 31)
        header = next(unpacker)
        out = []
        for meta, like in zip(header["metas"], leaves):
            buf = next(unpacker)
            if meta["dtype"] == "bfloat16":
                arr = np.frombuffer(buf, np.uint16).reshape(meta["shape"])
                arr = jnp.asarray(arr.view(jnp.bfloat16))
            else:
                arr = jnp.asarray(np.frombuffer(
                    buf, np.dtype(meta["dtype"])).reshape(meta["shape"]))
            out.append(arr)
    return jax.tree.unflatten(treedef, out), header.get("extra", {})


class CheckpointManager:
    """Rolling checkpoints with async save and retention.

    save() snapshots on the calling thread's values but writes on a
    background thread (double-buffered — training never blocks on disk),
    mirroring how a real cluster writes to replicated object storage.
    """

    def __init__(self, directory: str | Path, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    def _path(self, step: int) -> Path:
        return self.dir / f"ckpt_{step:08d}.msgpack"

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_checkpoint(self._path(step), host_tree, extra)
            self._gc()

        if self.async_save:
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()
        else:
            work()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("ckpt_*.msgpack"))
        for old in ckpts[:-self.keep]:
            old.unlink(missing_ok=True)

    def latest_step(self) -> Optional[int]:
        ckpts = sorted(self.dir.glob("ckpt_*.msgpack"))
        if not ckpts:
            return None
        return int(ckpts[-1].stem.split("_")[1])

    def restore_or_init(self, tree_like, init_fn):
        """Resume from the newest checkpoint or initialize fresh.
        Returns (tree, extra, step)."""
        self.wait()
        step = self.latest_step()
        if step is None:
            return init_fn(), {}, 0
        tree, extra = load_checkpoint(self._path(step), tree_like)
        return tree, extra, step
