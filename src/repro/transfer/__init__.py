"""Cross-pod transfer layer: versioned wire format + transports.

``wire`` encodes FlatParams payloads (dense buffers or compress_flat
top-k + int8 deltas) into self-describing checksummed byte frames;
``transport`` carries them.  The simulator and the pod schemes put REAL
bytes on the wire through this package — transfer sizes are measured,
not assumed.
"""
from repro.transfer.transport import (LoopbackTransport, ProcessTransport,
                                      Transport, TransportError,
                                      TransportStats)
from repro.transfer.wire import (HEADER_BYTES, KIND_DENSE, KIND_SPARSE,
                                 WIRE_VERSION, WireError, WireMessage,
                                 decode, dense_frame_bytes, encode,
                                 encode_dense, encode_sparse,
                                 sparse_frame_bytes)

__all__ = [
    "LoopbackTransport", "ProcessTransport", "Transport", "TransportError",
    "TransportStats",
    "HEADER_BYTES", "KIND_DENSE", "KIND_SPARSE", "WIRE_VERSION",
    "WireError", "WireMessage", "decode", "dense_frame_bytes", "encode",
    "encode_dense", "encode_sparse", "sparse_frame_bytes",
]
