"""Transports that carry wire frames (transfer/wire.py) between client and
server.

``Transport`` is the abstract protocol the Coordinator
(protocol/coordinator.py) drives on BOTH transfer legs — upload result
frames at submit, per-shard handout frames at issue: frames are
addressed by message id (results travel concurrently and complete out of
order, so a FIFO queue would mis-deliver), byte counts are the REAL
encoded frame lengths, and a frame is only ever delivered once.

* ``LoopbackTransport`` — the in-memory reference implementation the
  simulator and the pod schemes (runtime/vc_runtime.py::
  compressed_assimilate) ride.
* ``ProcessTransport`` — the proof the interface is not loopback-shaped:
  frames cross a REAL OS process boundary.  A broker process (plain
  CPython, no jax) owns the in-flight frame store; send/recv/drop are
  length-prefixed RPCs over a localhost TCP socket.  A production
  transport (gRPC / object store) implements the same three methods.
"""
from __future__ import annotations

import abc
import itertools
import socket
import struct
import subprocess
import sys
from dataclasses import dataclass, field
from typing import Dict


@dataclass
class TransportStats:
    frames_sent: int = 0
    bytes_sent: int = 0
    frames_recv: int = 0
    bytes_recv: int = 0
    frames_dropped: int = 0        # sent but never delivered (preemption,
    bytes_dropped: int = 0         # timeout reassignment, torn frames)


class TransportError(RuntimeError):
    pass


class Transport(abc.ABC):
    """Message-id-addressed frame carrier with real byte accounting."""

    stats: TransportStats

    @abc.abstractmethod
    def send(self, frame: bytes) -> int:
        """Put one encoded frame on the wire; returns its message id."""

    @abc.abstractmethod
    def recv(self, msg_id: int) -> bytes:
        """Take delivery of a frame (exactly once); raises TransportError
        if the id is unknown or already delivered/dropped."""

    @abc.abstractmethod
    def drop(self, msg_id: int) -> None:
        """Discard an in-flight frame (the sender died / the result timed
        out); the bytes were still spent.  Idempotent."""

    @property
    @abc.abstractmethod
    def in_flight(self) -> int:
        """Number of frames sent but neither delivered nor dropped."""


@dataclass
class LoopbackTransport(Transport):
    """In-memory message-id-addressed transport with real byte accounting."""

    stats: TransportStats = field(default_factory=TransportStats)
    _inflight: Dict[int, bytes] = field(default_factory=dict)
    _ids: "itertools.count" = field(default_factory=itertools.count)

    def send(self, frame: bytes) -> int:
        if not isinstance(frame, (bytes, bytearray)):
            raise TypeError(f"transport carries bytes, got {type(frame)}")
        mid = next(self._ids)
        self._inflight[mid] = bytes(frame)
        self.stats.frames_sent += 1
        self.stats.bytes_sent += len(frame)
        return mid

    def recv(self, msg_id: int) -> bytes:
        frame = self._inflight.pop(msg_id, None)
        if frame is None:
            raise TransportError(f"no in-flight frame with id {msg_id}")
        self.stats.frames_recv += 1
        self.stats.bytes_recv += len(frame)
        return frame

    def drop(self, msg_id: int) -> None:
        frame = self._inflight.pop(msg_id, None)
        if frame is not None:
            self.stats.frames_dropped += 1
            self.stats.bytes_dropped += len(frame)

    @property
    def in_flight(self) -> int:
        return len(self._inflight)


# ---------------------------------------------------------------------------
# ProcessTransport: frames cross a real OS process boundary
# ---------------------------------------------------------------------------

# The broker is deliberately a self-contained stdlib-only script run via
# ``python -c`` — it must not import jax (slow, fork-unsafe) or repro (the
# in-flight store is just bytes).  RPC framing, little-endian:
#   request:  op u8 ('S'end | 'R'ecv | 'D'rop | 'Q'uery | 'X' exit)
#             | mid u64 | body_len u64 | body
#   response: status u8 ('O' ok | 'E' unknown id)
#             | value u64 (drop: dropped frame length; query: store size)
#             | body_len u64 | body (recv: the frame)
# On connect the broker sends its PID (u64) so callers can verify the
# frames really live in another process.
_BROKER_SRC = r"""
import os, socket, struct, sys

def rd(c, n):
    b = b""
    while len(b) < n:
        ch = c.recv(n - len(b))
        if not ch:
            raise SystemExit(0)
        b += ch
    return b

def resp(c, ok, value=0, body=b""):
    c.sendall((b"O" if ok else b"E")
              + struct.pack("<QQ", value, len(body)) + body)

c = socket.create_connection(("127.0.0.1", int(sys.argv[1])))
c.sendall(struct.pack("<Q", os.getpid()))
store = {}
while True:
    op = rd(c, 1)
    mid, ln = struct.unpack("<QQ", rd(c, 16))
    body = rd(c, ln) if ln else b""
    if op == b"S":
        store[mid] = body
        resp(c, True)
    elif op == b"R":
        f = store.pop(mid, None)
        resp(c, f is not None, body=f or b"")
    elif op == b"D":
        f = store.pop(mid, None)
        resp(c, f is not None, value=len(f) if f is not None else 0)
    elif op == b"Q":
        resp(c, True, value=len(store))
    else:
        c.close()
        raise SystemExit(0)
"""

_REQ = struct.Struct("<QQ")
_LEN = struct.Struct("<Q")
_RSP = struct.Struct("<QQ")


class ProcessTransport(Transport):
    """Frames held by a broker in ANOTHER OS process, carried over a real
    localhost TCP socket.  Same contract as LoopbackTransport — the
    Coordinator cannot tell them apart except by ``broker_pid`` — but
    every byte genuinely leaves this process and comes back.

    Use as a context manager (or call ``close()``) so the broker process
    is reaped."""

    def __init__(self, timeout_s: float = 60.0):
        self.stats = TransportStats()
        self._ids = itertools.count()
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.bind(("127.0.0.1", 0))
        srv.listen(1)
        port = srv.getsockname()[1]
        self._conn = None
        self._proc = subprocess.Popen([sys.executable, "-c", _BROKER_SRC,
                                       str(port)])
        srv.settimeout(timeout_s)
        # if the handshake fails at ANY point (accept timeout, connection
        # reset, short PID read) the broker must be reaped here — the
        # constructor raising means no ProcessTransport exists to close(),
        # and an orphaned Popen handle leaks a live OS process
        try:
            try:
                self._conn, _ = srv.accept()
            finally:
                srv.close()
            self._conn.settimeout(timeout_s)
            (self.broker_pid,) = _LEN.unpack(self._read(8))
        except BaseException:
            if self._conn is not None:
                self._conn.close()
                self._conn = None
            self._proc.kill()
            self._proc.wait()
            self._proc = None
            raise

    # -- rpc plumbing -------------------------------------------------------
    def _read(self, n: int) -> bytes:
        buf = b""
        while len(buf) < n:
            chunk = self._conn.recv(n - len(buf))
            if not chunk:
                raise TransportError("broker process closed the connection")
            buf += chunk
        return buf

    def _rpc(self, op: bytes, mid: int, body: bytes = b""):
        self._conn.sendall(op + _REQ.pack(mid, len(body)) + body)
        status = self._read(1)
        value, ln = _RSP.unpack(self._read(_RSP.size))
        payload = self._read(ln) if ln else b""
        return status == b"O", value, payload

    # -- Transport ----------------------------------------------------------
    def send(self, frame: bytes) -> int:
        if not isinstance(frame, (bytes, bytearray)):
            raise TypeError(f"transport carries bytes, got {type(frame)}")
        mid = next(self._ids)
        ok, _, _ = self._rpc(b"S", mid, bytes(frame))
        if not ok:
            raise TransportError(f"broker rejected frame {mid}")
        self.stats.frames_sent += 1
        self.stats.bytes_sent += len(frame)
        return mid

    def recv(self, msg_id: int) -> bytes:
        ok, _, frame = self._rpc(b"R", msg_id)
        if not ok:
            raise TransportError(f"no in-flight frame with id {msg_id}")
        self.stats.frames_recv += 1
        self.stats.bytes_recv += len(frame)
        return frame

    def drop(self, msg_id: int) -> None:
        ok, ln, _ = self._rpc(b"D", msg_id)
        if ok:
            self.stats.frames_dropped += 1
            self.stats.bytes_dropped += int(ln)

    @property
    def in_flight(self) -> int:
        ok, ln, _ = self._rpc(b"Q", 0)
        return int(ln)

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        if getattr(self, "_conn", None) is not None:
            try:
                self._conn.sendall(b"X" + _REQ.pack(0, 0))
            except OSError:
                pass
            self._conn.close()
            self._conn = None
        if getattr(self, "_proc", None) is not None:
            try:
                self._proc.wait(timeout=5)
            except subprocess.TimeoutExpired:
                self._proc.kill()
                self._proc.wait()
            self._proc = None

    def __enter__(self) -> "ProcessTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
