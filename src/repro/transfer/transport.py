"""Transports that carry wire frames (transfer/wire.py) between client and
server.

``LoopbackTransport`` is the in-memory reference implementation the
simulator (core/simulator.py) and the pod schemes (core/baselines.py,
runtime/vc_runtime.py::compressed_assimilate) ride: frames are addressed
by message id (results travel concurrently and complete out of order, so
a FIFO queue would mis-deliver), byte counts are the REAL encoded frame
lengths, and a frame is only ever delivered once.  A production transport
(gRPC / object store) implements the same three methods.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict


@dataclass
class TransportStats:
    frames_sent: int = 0
    bytes_sent: int = 0
    frames_recv: int = 0
    bytes_recv: int = 0
    frames_dropped: int = 0        # sent but never delivered (preemption,
    bytes_dropped: int = 0         # timeout reassignment, torn frames)


class TransportError(RuntimeError):
    pass


@dataclass
class LoopbackTransport:
    """In-memory message-id-addressed transport with real byte accounting."""

    stats: TransportStats = field(default_factory=TransportStats)
    _inflight: Dict[int, bytes] = field(default_factory=dict)
    _ids: "itertools.count" = field(default_factory=itertools.count)

    def send(self, frame: bytes) -> int:
        """Put one encoded frame on the wire; returns its message id."""
        if not isinstance(frame, (bytes, bytearray)):
            raise TypeError(f"transport carries bytes, got {type(frame)}")
        mid = next(self._ids)
        self._inflight[mid] = bytes(frame)
        self.stats.frames_sent += 1
        self.stats.bytes_sent += len(frame)
        return mid

    def recv(self, msg_id: int) -> bytes:
        """Take delivery of a frame (exactly once)."""
        frame = self._inflight.pop(msg_id, None)
        if frame is None:
            raise TransportError(f"no in-flight frame with id {msg_id}")
        self.stats.frames_recv += 1
        self.stats.bytes_recv += len(frame)
        return frame

    def drop(self, msg_id: int) -> None:
        """Discard an in-flight frame (the sender died / the result timed
        out); the bytes were still spent."""
        frame = self._inflight.pop(msg_id, None)
        if frame is not None:
            self.stats.frames_dropped += 1
            self.stats.bytes_dropped += len(frame)

    @property
    def in_flight(self) -> int:
        return len(self._inflight)
