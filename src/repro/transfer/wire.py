"""Wire format v3 — what a FlatParams payload looks like as BYTES.

Until now the cross-pod payloads (full flat buffers, or the compress_flat
top-k + int8 deltas of core/compression.py) only ever existed as device
arrays, and "bytes on the wire" was a number the simulator made up
(``SimConfig.param_bytes``).  This module makes the bytes real: every
payload is encoded into a self-describing, versioned, checksummed frame
that an actual transport (transfer/transport.py) can carry, and whose
length IS the transfer size.

Frame layout (little-endian, fixed 68-byte header + body; version 3
frames append one ``weight f32`` field before the crc — 72 bytes)::

    magic    4s   b"VCWF"
    version  u16  wire format version (this module speaks 3; a frame is
                  EMITTED at the oldest version that can express it, so
                  dense/sparse/shard frames stay version 2 byte-for-byte)
    kind     u8   0 = DENSE (raw flat buffer), 1 = SPARSE (top-k + int8),
                  2 = SHARD (one contiguous ShardedTreeSpec segment of the
                  server bus — the DOWNLOAD/redistribution leg),
                  3 = AGG (v3 only: ONE merged, already-assimilated frame
                  from an edge aggregator — dense body + summed client
                  weight in the v3 ``weight`` header field)
    dtype    u8   dense/shard payload dtype code (0=f32, 1=bf16, 2=f16)
    n        u64  logical element count of the (padded) flat buffer
                  (shard: element count of THIS segment, == shard_len)
    k        u64  surviving elements (dense: == n; shard: shard index)
    block    u32  int8 quantization block (sparse; shard: n_shards;
                  dense: 0)
    density  f32  sparse density budget (dense/shard: 1.0)
    round    u32  error-feedback round counter (bookkeeping)
    res_norm f32  l2 norm of the residual carried AFTER this payload
                  (error-feedback bookkeeping: the receiver can monitor
                  how much update mass is still in flight client-side)
    len_val  u64  byte length of the values section
    len_scl  u64  byte length of the scales section
    len_idx  u64  byte length of the indices section
    weight   f32  (v3 headers ONLY) summed client mass of an aggregate
                  frame: 1 - prod(per-assimilation retention) over the
                  results the aggregator folded; 0 <= weight <= 1
    crc      u32  crc32 over header-sans-crc || body — a bit flip ANYWHERE
                  in the frame (including the n/k/density header fields)
                  fails the checksum, not just body corruption

Versioning rules: the magic/version pair is checked FIRST; a decoder
rejects frames with a version newer than it speaks (no silent best-effort
parsing), and any field may only be reinterpreted by bumping the version
— v2 did exactly that: it added kind 2 and reinterpreted the ``k`` /
``block`` header fields for that kind only, and v3 adds kind 3 plus the
``weight`` header field (v1/v2 frames decode unchanged, and the old
kinds are still EMITTED as version-2 frames so their byte counts never
move).  Truncated, oversized, or bit-flipped frames fail the length/crc
checks and raise ``WireError`` — a torn transfer is never assimilated
(the paper's fault-tolerance requirement: dropping a payload is always
safe, applying a corrupt one never is).
"""
from __future__ import annotations

import struct
import zlib
from typing import NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import CompressedDelta

MAGIC = b"VCWF"
WIRE_VERSION = 3

KIND_DENSE = 0
KIND_SPARSE = 1
KIND_SHARD = 2                 # one contiguous segment of the server bus
KIND_AGG = 3                   # merged pre-assimilated frame (v3 only)

# emission rule: a frame is written at the OLDEST version that can express
# it, so dense/sparse/shard frames keep the v2 68-byte header (every
# pinned byte count stays exact) and only aggregate frames pay for v3's
# extra ``weight f32``
_EMIT_VERSION = 2
_HDR = struct.Struct("<4sHBBQQIfIfQQQ")      # v1/v2 header minus the crc
_HDR3 = struct.Struct("<4sHBBQQIfIfQQQf")    # v3: + weight f32
_CRC = struct.Struct("<I")
_PEEK = struct.Struct("<4sH")                # magic/version, checked FIRST
HEADER_BYTES = _HDR.size + _CRC.size
HEADER_BYTES_V3 = _HDR3.size + _CRC.size


def _frame(header_wo_crc: bytes, body: bytes) -> bytes:
    """Assemble a frame: crc covers header-sans-crc || body, so a flip in
    ANY field (not just the payload) fails validation."""
    return (header_wo_crc
            + _CRC.pack(zlib.crc32(body, zlib.crc32(header_wo_crc)))
            + body)

_DTYPE_CODES = {"float32": 0, "bfloat16": 1, "float16": 2}
_CODE_DTYPES = {v: k for k, v in _DTYPE_CODES.items()}


class WireError(ValueError):
    """Frame failed validation (magic/version/length/crc) — do NOT
    assimilate anything from it."""


class WireMessage(NamedTuple):
    kind: int                     # KIND_DENSE|KIND_SPARSE|KIND_SHARD|KIND_AGG
    payload: Union[np.ndarray, CompressedDelta]
    round: int                    # error-feedback round counter
    residual_norm: float          # client-side residual mass after sending
    shard: int = 0                # KIND_SHARD: segment index on the bus
    n_shards: int = 1             # KIND_SHARD: total segments of the bus
    weight: float = 1.0           # KIND_AGG: summed client mass (v3 header)


class AggregatePayload(NamedTuple):
    """What an edge aggregator submits upstream: its merged (already
    assimilated) fold state plus the summed client mass it represents.
    Travels as a ``KIND_AGG`` v3 frame; the hub folds it with
    ``ServerScheme.assimilate_aggregate`` instead of the per-result path
    (no scheme encode, no residual ledger — both ran at the edge)."""

    buf: np.ndarray               # merged flat buffer (padded bus layout)
    weight: float                 # 1 - prod(retention) over folded results


def dense_frame_bytes(n: int, dtype: str = "float32") -> int:
    """Exact frame length of a dense buffer payload."""
    itemsize = 2 if dtype in ("bfloat16", "float16") else 4
    return HEADER_BYTES + n * itemsize


def agg_frame_bytes(n: int, dtype: str = "float32") -> int:
    """Exact frame length of one merged aggregate frame (v3 header)."""
    itemsize = 2 if dtype in ("bfloat16", "float16") else 4
    return HEADER_BYTES_V3 + n * itemsize


def shard_frame_bytes(shard_len: int, dtype: str = "float32") -> int:
    """Exact frame length of one handout segment (same body as dense)."""
    return dense_frame_bytes(shard_len, dtype)


def sparse_frame_bytes(k: int, block: int = 256) -> int:
    """Exact frame length of a top-k + int8 payload: k int8 values,
    ceil(k/block) f32 scales, k int32 indices."""
    return HEADER_BYTES + k + (-(-k // block)) * 4 + k * 4


def _host(x) -> np.ndarray:
    return np.asarray(jax.device_get(x))


def _dense_bytes(buf: np.ndarray):
    name = str(buf.dtype)
    if name == "bfloat16":
        return _DTYPE_CODES[name], buf.view(np.uint16).tobytes()
    if name not in _DTYPE_CODES:
        raise WireError(f"unsupported dense wire dtype {name}")
    return _DTYPE_CODES[name], buf.tobytes()


def encode_dense(buf, *, round: int = 0, residual_norm: float = 0.0) -> bytes:
    """Encode a full flat buffer (the uncompressed payload kind)."""
    arr = _host(buf).reshape(-1)
    code, raw = _dense_bytes(arr)
    header = _HDR.pack(MAGIC, _EMIT_VERSION, KIND_DENSE, code,
                       arr.size, arr.size, 0, 1.0,
                       int(round), float(residual_norm),
                       len(raw), 0, 0)
    return _frame(header, raw)


def encode_aggregate(buf, *, weight: float, round: int = 0,
                     residual_norm: float = 0.0) -> bytes:
    """Encode an edge aggregator's merged upstream frame (KIND_AGG): the
    dense fold-state body plus the summed client mass in the v3 header's
    ``weight`` field.  The weight is the only thing distinguishing the
    body from a dense payload — it tells the hub how much of its own
    pre-window mass the merge already retains (see
    ``ServerScheme.assimilate_aggregate``)."""
    w = float(weight)
    if not 0.0 <= w <= 1.0:
        raise WireError(f"aggregate weight {w} outside [0, 1]")
    arr = _host(buf).reshape(-1)
    code, raw = _dense_bytes(arr)
    header = _HDR3.pack(MAGIC, 3, KIND_AGG, code,
                        arr.size, arr.size, 0, 1.0,
                        int(round), float(residual_norm),
                        len(raw), 0, 0, w)
    return _frame(header, raw)


def encode_shard(seg, *, shard: int, n_shards: int, round: int = 0) -> bytes:
    """Encode one contiguous handout segment of the server bus (the
    DOWNLOAD leg): shard ``shard`` of ``n_shards``, laid out by the bus's
    ShardedTreeSpec shard table.  ``k`` carries the shard index and
    ``block`` the shard count (v2 reinterpretation, KIND_SHARD only)."""
    if not 0 <= shard < n_shards:
        raise WireError(f"shard {shard} out of range 0..{n_shards - 1}")
    arr = _host(seg).reshape(-1)
    code, raw = _dense_bytes(arr)
    header = _HDR.pack(MAGIC, _EMIT_VERSION, KIND_SHARD, code,
                       arr.size, int(shard), int(n_shards), 1.0,
                       int(round), 0.0,
                       len(raw), 0, 0)
    return _frame(header, raw)


# fused encode leg: the body (values || scales || indices bytes) is packed
# into ONE device buffer (kernels/ref.py::pack_body — bitcast+concat, zero
# arithmetic, so the bytes are exactly the payload arrays' bytes; the
# Pallas single-launch form is kernels/sparse_pack.py) and crosses the
# device->host boundary in ONE transfer, vs the three array transfers +
# Python concat the old encoder paid per frame.  jit caches by (k, ng)
# shape, so steady-state rounds reuse the compiled pack.
_pack_body_dev = None


def _packed_sparse_body(p: CompressedDelta) -> bytes:
    global _pack_body_dev
    if _pack_body_dev is None:
        from repro.kernels import ref as _kref
        _pack_body_dev = jax.jit(_kref.pack_body)
    return _host(_pack_body_dev(p.values, p.scales, p.indices)).tobytes()


def encode_sparse(p: CompressedDelta, *, round: int = 0,
                  residual_norm: float = 0.0) -> bytes:
    """Encode a compress_flat payload (global top-k + int8).  The body is
    device-packed and crosses to the host as one buffer; frame bytes are
    identical to the three-section ``tobytes`` concat they replace."""
    k = int(p.values.size)
    ng = int(p.scales.size)
    n = 1
    for s in p.shape:
        n *= int(s)
    body = _packed_sparse_body(p)
    header = _HDR.pack(MAGIC, _EMIT_VERSION, KIND_SPARSE, 0,
                       n, k, int(p.block), float(p.density),
                       int(round), float(residual_norm),
                       k, 4 * ng, 4 * k)
    return _frame(header, body)


def encode(payload, *, round: int = 0, residual_norm: float = 0.0) -> bytes:
    """Dispatch on payload type: buffers go dense, CompressedDelta sparse,
    AggregatePayload rides the v3 aggregate frame."""
    if isinstance(payload, CompressedDelta):
        return encode_sparse(payload, round=round, residual_norm=residual_norm)
    if isinstance(payload, AggregatePayload):
        return encode_aggregate(payload.buf, weight=payload.weight,
                                round=round, residual_norm=residual_norm)
    return encode_dense(payload, round=round, residual_norm=residual_norm)


def decode(frame: bytes) -> WireMessage:
    """Validate and decode one frame.  Raises WireError on ANY structural
    problem — short frame, bad magic, unknown version, length mismatch,
    crc mismatch — so a torn transfer can never be assimilated."""
    if len(frame) < _PEEK.size:
        raise WireError(f"frame too short: {len(frame)} < {_PEEK.size}")
    magic, version = _PEEK.unpack_from(frame)
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r}")
    if version > WIRE_VERSION:
        raise WireError(f"wire version {version} newer than spoken "
                        f"{WIRE_VERSION}")
    # the header struct is selected by the (already validated) version:
    # v1/v2 = 68 bytes, v3 = 72 (trailing weight f32); the crc always
    # covers the whole header-sans-crc, so the weight field is protected
    hdr = _HDR3 if version >= 3 else _HDR
    hdr_bytes = hdr.size + _CRC.size
    if len(frame) < hdr_bytes:
        raise WireError(f"frame too short: {len(frame)} < {hdr_bytes}")
    fields = hdr.unpack_from(frame)
    (_, _, kind, dcode, n, k, block, density, rnd, res_norm,
     len_v, len_s, len_i) = fields[:13]
    weight = fields[13] if version >= 3 else 1.0
    (crc,) = _CRC.unpack_from(frame, hdr.size)
    body = frame[hdr_bytes:]
    if len(body) != len_v + len_s + len_i:
        raise WireError(f"torn frame: body {len(body)}B != declared "
                        f"{len_v + len_s + len_i}B")
    if zlib.crc32(body, zlib.crc32(frame[:hdr.size])) != crc:
        raise WireError("crc mismatch (corrupt frame)")
    if kind == KIND_AGG and version < 3:
        raise WireError(f"kind {KIND_AGG} (aggregate) requires wire v3, "
                        f"got v{version}")
    if kind in (KIND_DENSE, KIND_SHARD, KIND_AGG):
        dtype = _CODE_DTYPES.get(dcode)
        if dtype is None:
            raise WireError(f"unknown dense dtype code {dcode}")
        if dtype == "bfloat16":
            arr = np.frombuffer(body, np.uint16).view(jnp.bfloat16)
        else:
            arr = np.frombuffer(body, np.dtype(dtype))
        if arr.size != n:
            raise WireError(f"dense payload {arr.size} elements != "
                            f"declared n={n}")
        if kind == KIND_SHARD:
            # v2: k = shard index, block = n_shards
            if not (block > 0 and 0 <= k < block):
                raise WireError(f"shard index {k} out of range for "
                                f"{block} shards")
            return WireMessage(KIND_SHARD, arr, rnd, res_norm,
                               shard=int(k), n_shards=int(block))
        if kind == KIND_AGG:
            if not 0.0 <= weight <= 1.0:
                raise WireError(f"aggregate weight {weight} outside [0, 1]")
            return WireMessage(KIND_AGG, arr, rnd, res_norm,
                               weight=float(weight))
        return WireMessage(KIND_DENSE, arr, rnd, res_norm)
    if kind == KIND_SPARSE:
        vals = np.frombuffer(body[:len_v], np.int8)
        scls = np.frombuffer(body[len_v:len_v + len_s], np.float32)
        idxs = np.frombuffer(body[len_v + len_s:], np.int32)
        if vals.size != k or idxs.size != k:
            raise WireError(f"sparse sections disagree with k={k}: "
                            f"{vals.size} values / {idxs.size} indices")
        if block <= 0 or scls.size != -(-k // block):
            raise WireError(f"scale count {scls.size} != ceil({k}/{block})")
        if k > n:
            raise WireError(f"k={k} exceeds buffer length n={n}")
        payload = CompressedDelta(values=jnp.asarray(vals),
                                  scales=jnp.asarray(scls),
                                  indices=jnp.asarray(idxs),
                                  shape=(int(n),), density=float(density),
                                  block=int(block))
        return WireMessage(KIND_SPARSE, payload, rnd, res_norm)
    raise WireError(f"unknown frame kind {kind}")
