"""Content-addressed handout frame cache: encode once, serve millions.

The delta-handout ledger (protocol/coordinator.py) made each client's
download cheap — but the coordinator still ENCODED a fresh wire frame
per client per changed shard: O(clients x changed-bytes) work per round,
which caps the read path far below "millions of users pulling the
model".  This cache closes that gap: the bus is chunked by shard, each
chunk's bytes are hashed once per write-version, and the encoded frame
is kept in a round-addressed immutable cache keyed by

    (round, chunk, content_hash)

``round`` is part of the key because the wire header embeds it
(``wire.encode_shard(..., round=...)``): identical chunk bytes at two
different rounds are two different frames, and the cache must be
byte-identical to a fresh per-client encode.  ``content_hash`` makes a
stale entry structurally unreachable — a content change produces a new
key, it never serves old bytes under a new version.

Bounded memory (the retention watermark):

* **Within a round** an entry is superseded when its chunk's content
  moves (handouts always ship the CURRENT bus content — an old
  content's frame can never be served again), so at most one live frame
  per (chunk, round).
* **Across rounds** an explicit retention watermark evicts every frame
  whose round fell behind ``max_round_seen - keep_rounds + 1``: once
  every requester's round passed R, round-R frames are unreachable (the
  round is in the header, so a caught-up reader at round R' > R could
  never be served them anyway).  Requests from BELOW the watermark
  (a rewound restore) bypass the cache — encoded fresh, never stored,
  never wrong.

Total: at most ``n_chunks * keep_rounds`` frames resident, regardless
of how many clients/subscribers are served — the invariant the
1M-subscriber scenarios lean on (tests/test_handout.py pins it).

The cache is a pure encode-memoizer: a miss is only a wasted encode,
never wrong bytes, because the key binds the exact (round, content)
pair that determines the frame.
"""
from __future__ import annotations

import hashlib
from typing import Callable, Dict, Optional, Tuple

import numpy as np


def chunk_hash(data: np.ndarray) -> bytes:
    """Content hash of one bus chunk (16-byte blake2b over the raw
    bytes).  Computed once per (chunk, write-version) — the caller
    memoizes through ``HandoutCache.get``."""
    return hashlib.blake2b(np.ascontiguousarray(data).view(np.uint8),
                           digest_size=16).digest()


class HandoutCache:
    """Round-addressed immutable frame cache for the download leg.

    ``get`` is the only hot-path entry point: it returns the encoded
    frame for (round, chunk, current content), encoding at most once
    per (round, chunk, write-version).  Serving stats (bytes served vs
    unique bytes encoded) accumulate here, so the dedup ratio of the
    whole download leg is an O(1) read."""

    def __init__(self, keep_rounds: int = 2):
        if keep_rounds < 1:
            raise ValueError("keep_rounds must be >= 1")
        self.keep_rounds = int(keep_rounds)
        # (round, chunk, content_hash) -> immutable frame bytes
        self._frames: Dict[Tuple[int, int, bytes], bytes] = {}
        # chunk -> {round -> key}: the live entry per (chunk, round),
        # replaced when the chunk's content moves within the round
        self._live: Dict[int, Dict[int, Tuple[int, int, bytes]]] = {}
        # chunk -> (write_version, digest): hash memo for the CURRENT
        # version only (old versions are never served again)
        self._hash_memo: Dict[int, Tuple[int, bytes]] = {}
        self.watermark = 0              # lowest round still cacheable
        self._max_round = -1
        # ---- serving stats ------------------------------------------------
        self.encodes = 0                # cache misses (fresh encodes)
        self.encoded_bytes = 0          # unique bytes encoded
        self.hits = 0                   # frames served from cache
        self.served_frames = 0          # every frame returned by get()
        self.served_bytes = 0           # summed lengths of served frames
        self.evicted = 0                # frames dropped by the watermark

    # -- introspection -------------------------------------------------------

    @property
    def frames_held(self) -> int:
        return len(self._frames)

    @property
    def bytes_held(self) -> int:
        return sum(len(f) for f in self._frames.values())

    @property
    def dedup_ratio(self) -> float:
        """bytes-served / unique-bytes-encoded (1.0 = no reuse)."""
        return self.served_bytes / max(self.encoded_bytes, 1)

    # -- the hot path --------------------------------------------------------

    def get(self, *, round: int, chunk: int, version: int,
            data: np.ndarray, encode: Callable[[], bytes]
            ) -> Tuple[bytes, bool]:
        """Frame for ``chunk`` at ``round`` with content ``data`` (the
        bus cache slice at write-version ``version``).  Returns
        ``(frame, fresh)`` where ``fresh`` is True iff this call paid
        the encode.  ``encode`` must be deterministic in (data, round,
        chunk) — that is what makes the cached frame byte-identical to
        a per-client encode."""
        if round > self._max_round:
            self._max_round = round
            new_mark = round - self.keep_rounds + 1
            if new_mark > self.watermark:
                self._evict_below(new_mark)
        if round < self.watermark:
            # rewound requester (e.g. issue after a checkpoint restore
            # cleared nothing but rounds went backwards): serve fresh,
            # never cache below the watermark
            frame = encode()
            self.encodes += 1
            self.encoded_bytes += len(frame)
            self._serve(frame)
            return frame, True
        digest = self._digest(chunk, version, data)
        key = (round, chunk, digest)
        frame = self._frames.get(key)
        if frame is not None:
            self.hits += 1
            self._serve(frame)
            return frame, False
        frame = encode()
        self.encodes += 1
        self.encoded_bytes += len(frame)
        per_round = self._live.setdefault(chunk, {})
        old = per_round.get(round)
        if old is not None:
            # content moved within the round: the old frame can never
            # be served again (handouts ship current content only)
            self._frames.pop(old, None)
            self.evicted += 1
        per_round[round] = key
        self._frames[key] = frame
        self._serve(frame)
        return frame, True

    def _serve(self, frame: bytes) -> None:
        self.served_frames += 1
        self.served_bytes += len(frame)

    # -- retention -----------------------------------------------------------

    def _evict_below(self, mark: int) -> None:
        """Advance the retention watermark: every frame from a round
        below ``mark`` is unreachable (callers' rounds are monotone) —
        drop it."""
        self.watermark = mark
        for chunk, per_round in list(self._live.items()):
            for rnd in [r for r in per_round if r < mark]:
                self._frames.pop(per_round.pop(rnd), None)
                self.evicted += 1
            if not per_round:
                del self._live[chunk]

    def reset(self) -> None:
        """Forget every frame and the round watermark (checkpoint
        restore: rounds may rewind; the serving stats survive — they
        describe the process, not the cache content)."""
        self._frames.clear()
        self._live.clear()
        self._hash_memo.clear()
        self.watermark = 0
        self._max_round = -1

    # -- internals -----------------------------------------------------------

    def _digest(self, chunk: int, version: int, data: np.ndarray) -> bytes:
        memo = self._hash_memo.get(chunk)
        if memo is not None and memo[0] == version:
            return memo[1]
        digest = chunk_hash(data)
        # current version only: old versions' content is never served
        # again, so the memo stays O(n_chunks)
        self._hash_memo[chunk] = (version, digest)
        return digest
