#!/usr/bin/env python
"""cProfile wrapper around run_simulation for a named scenario.

Prints the top-N cumulative-time hotspots (pstats), so per-event cost
claims are evidence-backed instead of guessed::

    PYTHONPATH=src python tools/profile_sim.py --scenario fleet_smoke
    PYTHONPATH=src python tools/profile_sim.py --scenario fleet_1k -n 30 \
        --sort tottime
    PYTHONPATH=src python tools/profile_sim.py --scenario handout_flash_10k

Any scenario from repro.scenarios.registry works; the probe task keeps
client compute out of the way, so what you see IS the event loop +
protocol + wire stack.  The ``handout_*`` subscriber scenarios profile
the read path: cache hits in transfer/handout_cache.py should dominate
over fresh encodes (that is the whole point of the cache).
"""
from __future__ import annotations

import argparse
import cProfile
import pstats
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def main(argv=None) -> int:
    from repro.scenarios.registry import SCENARIOS, get

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="fleet_smoke",
                    help="one of: " + ", ".join(sorted(SCENARIOS)))
    ap.add_argument("-n", "--top", type=int, default=20,
                    help="how many rows to print (default 20)")
    ap.add_argument("--sort", default="cumulative",
                    choices=["cumulative", "tottime", "ncalls"],
                    help="pstats sort key (default cumulative)")
    ap.add_argument("--dump", default=None,
                    help="optional path to write the raw .prof stats")
    args = ap.parse_args(argv)

    sc = get(args.scenario)
    prof = cProfile.Profile()
    t0 = time.perf_counter()
    prof.enable()
    res = sc.run()
    prof.disable()
    wall = time.perf_counter() - t0

    print(f"scenario {sc.name}: {res.events_processed} events in "
          f"{wall:.2f}s wall ({res.events_processed / max(wall, 1e-9):,.0f} "
          f"events/sec), {res.results_assimilated} results, "
          f"{res.preemptions} preemptions")
    print()
    stats = pstats.Stats(prof)
    stats.sort_stats(args.sort).print_stats(args.top)
    if args.dump:
        stats.dump_stats(args.dump)
        print(f"raw stats -> {args.dump}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
