#!/usr/bin/env python
"""vclint CLI — run the repo-native static analysis pass.

Usage::

    PYTHONPATH=src python -m tools.vclint [paths...]     # default: src/repro
    python tools/vclint.py --json                        # machine output
    python tools/vclint.py --no-baseline                 # raw violations
    python tools/vclint.py --update-baseline             # re-pin (shrink only)

Exit codes: 0 clean against baseline, 1 new violations (ratchet), 2 no
baseline pinned.  See docs/LINT.md for the rule catalog and suppression
syntax (``# vclint: disable=rule-name``).
"""
import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis import baseline as B                     # noqa: E402
from repro.analysis.framework import lint_paths              # noqa: E402
from repro.analysis.reporters import render_json, text_report  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="vclint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--json", action="store_true",
                    help="emit the JSON report (consumed by "
                         "benchmarks/run.py --check)")
    ap.add_argument("--baseline", type=Path,
                    default=REPO_ROOT / B.DEFAULT_BASELINE,
                    help="baseline file (default: "
                         "results/BASELINE_vclint.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip the ratchet; exit 1 iff any violation")
    ap.add_argument("--update-baseline", action="store_true",
                    help="re-pin the baseline from this run (counts may "
                         "only shrink)")
    args = ap.parse_args(argv)

    paths = [Path(p) for p in (args.paths or [REPO_ROOT / "src" / "repro"])]
    report = lint_paths(paths, repo_root=REPO_ROOT)

    if args.json:
        sys.stdout.write(render_json(report))
    else:
        print(text_report(report))

    if args.update_baseline:
        B.write_baseline(args.baseline, report)
        print(f"vclint: baseline pinned at {args.baseline} "
              f"(total={report.total})")
        return B.EXIT_CLEAN

    if args.no_baseline:
        return B.EXIT_VIOLATIONS if report.total else B.EXIT_CLEAN

    code, msgs = B.check_ratchet(report, B.load_baseline(args.baseline))
    for m in msgs:
        print(m, file=sys.stderr)
    return code


if __name__ == "__main__":
    raise SystemExit(main())
