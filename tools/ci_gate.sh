#!/usr/bin/env bash
# CI gate: the fast test selection plus the perf ratchet, in one command.
#
#   tools/ci_gate.sh              # fast tests + pallas launch-count gate
#   tools/ci_gate.sh --full       # full tier-1 suite (slow tests included)
#                                 # + launch-count gate
#
# The fast gate (tools/fast_gate.sh) runs everything not marked `slow` —
# including the examples' --smoke runs (tests/test_examples.py) and the
# pinned simulation bit-identity regression (tests/test_protocol.py).
# `python -m benchmarks.run --check` then fails if any suite's fused
# pallas launch counts regress versus results/BASELINE_launches.json
# (ratchet intentionally with --update-baseline).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--full" ]]; then
    shift
    python -m pytest -x -q "$@"
else
    tools/fast_gate.sh "$@"
fi
python -m benchmarks.run --check
echo "[ci-gate] all green"
