#!/usr/bin/env bash
# CI gate: the fast test selection plus the perf ratchet, in one command.
#
#   tools/ci_gate.sh              # fast tests + pallas launch-count gate
#   tools/ci_gate.sh --full       # full tier-1 suite (slow tests included)
#                                 # + launch-count gate
#
# The static tier runs FIRST: tools/vclint.py checks the repo-native
# protocol/wire/kernel invariants (docs/LINT.md) against the committed
# baseline results/BASELINE_vclint.json — a lint regression fails the
# gate before any test executes.
# The fast gate (tools/fast_gate.sh) runs everything not marked `slow` —
# including the examples' --smoke runs (tests/test_examples.py), the
# pinned simulation bit-identity regression (tests/test_protocol.py)
# and the vclint ratchet again as a tier-1 test (tests/test_vclint.py).
# A vc_serve kill-and-resume pass then proves the resume path stays
# monotone (rounds/uids continue from the checkpoint, never rewind), and
# `python -m benchmarks.run --check` fails if any suite's fused pallas
# launch counts regress versus results/BASELINE_launches.json, if the
# fleet events/sec floor is missed, or if any compression kernel trips
# the per-kernel roofline ratchet versus results/BASELINE_roofline.json
# (HLO traffic fraction + measured-bandwidth floor; docs/ROOFLINE.md).
# Ratchet intentionally with --update-baseline.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# static tier: parse-time invariant checks, ratcheted against the
# committed baseline (exit 2 = baseline never pinned)
python -m tools.vclint
echo "[ci-gate] vclint static tier clean"

if [[ "${1:-}" == "--full" ]]; then
    shift
    python -m pytest -x -q "$@"
else
    tools/fast_gate.sh "$@"
fi

# kill-and-resume: run the wall-clock coordinator twice against the same
# checkpoint dir — the second run must RESUME (round 2 onward), never
# restart at round 0 or overwrite earlier checkpoint steps
resume_dir=$(mktemp -d)
trap 'rm -rf "$resume_dir"' EXIT
python -m repro.launch.vc_serve --smoke --ckpt-dir "$resume_dir" \
    > "$resume_dir/first.log"
python -m repro.launch.vc_serve --smoke --ckpt-dir "$resume_dir" \
    > "$resume_dir/second.log"
grep -q "round 1:" "$resume_dir/first.log"
grep -q "resumed"  "$resume_dir/second.log"
grep -q "round 3:" "$resume_dir/second.log"
if grep -q "round 0:" "$resume_dir/second.log"; then
    echo "[ci-gate] FAIL: resumed vc_serve rewound to round 0" >&2
    exit 1
fi
echo "[ci-gate] vc_serve kill-and-resume: rounds stayed monotone"

# aggregation tier: the same wall-clock driver behind an edge aggregator
# (real broker process on every hop) — the hub must only ever see merged
# KIND_AGG frames on the upstream leg
tier_dir=$(mktemp -d)
trap 'rm -rf "$resume_dir" "$tier_dir"' EXIT
python -m repro.launch.vc_serve --smoke --tier --ckpt-dir "$tier_dir" \
    > "$tier_dir/tier.log"
grep -q "upstream agg frames" "$tier_dir/tier.log"
grep -q "results assimilated" "$tier_dir/tier.log"
echo "[ci-gate] vc_serve aggregation-tier smoke completed"

# handout-serve smoke: read-only subscribers pulling cached frames
# through the REAL broker after every round — the serve line proves the
# content-addressed cache deduplicates (encode once, serve many) and
# the run's frame-conservation invariants still hold with readers on
serve_dir=$(mktemp -d)
trap 'rm -rf "$resume_dir" "$tier_dir" "$serve_dir"' EXIT
python -m repro.launch.vc_serve --smoke --subscribers 16 \
    --ckpt-dir "$serve_dir" > "$serve_dir/serve.log"
grep -q "serve: round 1 16 subscribers" "$serve_dir/serve.log"
grep -q "dedup" "$serve_dir/serve.log"
echo "[ci-gate] vc_serve handout-serve smoke completed"

# fleet smoke: a 200-client preemptible scenario end to end through the
# scenario registry (probe task, real wire frames) — proves the fleet
# path stays runnable; throughput is gated separately by --check below
python -m repro.scenarios.registry --scenario fleet_smoke > /dev/null
echo "[ci-gate] fleet smoke scenario completed"

python -m benchmarks.run --check
echo "[ci-gate] all green"
