#!/usr/bin/env bash
# Fast iteration gate: the full tier-1 suite minus the slow-marked
# multi-device subprocess spawns and the real-SIGKILL fault-injection
# test (markers registered in pytest.ini).  PYTHONPATH is preset so it
# runs from any checkout without installation.
#
#   tools/fast_gate.sh            # -m "not slow"
#   tools/fast_gate.sh -k wire    # extra pytest args pass through
#
# The full gate (everything, including slow) is:
#   PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q -m "not slow" "$@"
