#!/usr/bin/env bash
# Fast iteration gate: the full tier-1 suite minus the slow-marked
# multi-device subprocess spawns and the real-SIGKILL fault-injection
# test (markers registered in pytest.ini).  PYTHONPATH is preset so it
# runs from any checkout without installation.
#
# The selection includes the static lint tier (tests/test_vclint.py,
# marker `lint`): tools/vclint.py's rules run over src/repro and the
# ratchet against results/BASELINE_vclint.json must hold.  Run the lint
# tier alone with `tools/fast_gate.sh -m lint`; see docs/LINT.md.
#
#   tools/fast_gate.sh            # -m "not slow"
#   tools/fast_gate.sh -k wire    # extra pytest args pass through
#
# The full gate (everything, including slow) is:
#   PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q -m "not slow" "$@"
