"""Capture the pinned simulation-regression fixture.

Runs every server scheme through ``run_simulation`` at a small fixed
configuration and records the observable results (wall clock, accuracy
trace, wire byte counts, store/scheduler counters) with full float
precision.  The committed output, ``results/PINNED_sim_regression.json``,
is the bit-identity contract of the protocol redesign:
``tests/test_protocol.py::test_pinned_regression`` re-runs the same
configurations and asserts EXACT equality — the Lease/Coordinator API may
restructure the plumbing, but it may not change a single simulated float.

Regenerate (only when an intentional semantic change is made):

  PYTHONPATH=src python tools/pin_sim_regression.py
"""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.baselines import (CompressedVCASGD, DCASGD, Downpour,
                                  EASGDFlatPod, EASGDPersistent, SyncBSP,
                                  VCASGD)
from repro.core.simulator import SimConfig, run_simulation
from repro.core.tasks import MLPTask, make_classification_data

OUT = Path(__file__).resolve().parents[1] / "results" / \
    "PINNED_sim_regression.json"

# one small shared workload; schemes that exercise the drop paths run with
# preemption on so lease release / residual bookkeeping is covered too
BASE = dict(n_param_servers=2, n_clients=3, tasks_per_client=2, n_shards=8,
            max_epochs=2, local_steps=2, subtask_compute_s=120.0, seed=5)
PREEMPT = dict(preemptible=True, mean_lifetime_s=900.0,
               restart_delay_s=60.0)

# name -> (scheme factory, config overrides).  Factories, not instances:
# schemes carry client-local state and every run must start fresh.
CASES = {
    "vc-asgd": (lambda: VCASGD(0.95), {}),
    "vc-asgd-preempt": (lambda: VCASGD(0.95), dict(PREEMPT)),
    "vc-asgd-compressed": (
        lambda: CompressedVCASGD(0.95, density=0.05), dict(PREEMPT)),
    "downpour": (lambda: Downpour(server_lr=0.5), {}),
    "dc-asgd": (lambda: DCASGD(server_lr=0.5, lam=0.05), {}),
    "easgd-persistent": (
        lambda: EASGDPersistent(beta=0.05), dict(PREEMPT)),
    "easgd-flat-pod": (lambda: EASGDFlatPod(n_replicas=3, beta=0.05), {}),
    "easgd-flat-pod-compressed": (
        lambda: EASGDFlatPod(n_replicas=3, beta=0.05,
                             compress_density=0.1), {}),
    "sync-bsp": (lambda: SyncBSP(8), {}),
    "vc-asgd-strong": (lambda: VCASGD(0.95), dict(consistency="strong")),
    # enough simultaneous results per PS that the pick policy matters:
    # pins the earliest-free server assignment (§IV-B contention model —
    # blind round-robin queued results behind a busy PS while another
    # idled)
    "vc-asgd-contended": (
        lambda: VCASGD(0.95),
        dict(n_param_servers=2, tasks_per_client=4, server_proc_s=45.0)),
}

# fleet-scale pins (PR 6): ProbeTask over the probe dataset (third tuple
# element "probe"), exercising the flat task protocol end to end — the
# O(1)-per-event loop with churn, the version-vector delta ledger over a
# sharded bus, and the bounded eval_stride accumulation.
FLEET_BASE = dict(n_param_servers=2, n_clients=120, tasks_per_client=1,
                  n_shards=240, max_epochs=2, local_steps=1,
                  timeout_s=1800.0, preemptible=True,
                  mean_lifetime_s=5400.0, restart_delay_s=120.0,
                  subtask_compute_s=120.0, server_proc_s=0.05, seed=7)
CASES.update({
    "fleet-churn": (lambda: VCASGD(0.95), dict(FLEET_BASE), "probe"),
    "fleet-sharded-bus": (
        lambda: VCASGD(0.95),
        dict(FLEET_BASE, bus_shards=4, seed=11), "probe"),
    "fleet-eval-stride": (
        lambda: VCASGD(0.95), dict(FLEET_BASE, eval_stride=8), "probe"),
})

# aggregation-tier pins (aggregation-tier PR).  `tier-flat-twin` and
# `tier-2level` are the SAME workload flat vs behind one aggregator over
# a single strong parameter server: fold relocation is exact there, so
# their final_accuracy (and the whole accuracy trace) must be
# bit-identical — asserted against each other by
# tests/test_protocol.py::test_pinned_tier_matches_flat_twin, not just
# against this fixture.  `tier-fleet` pins the multi-aggregator path
# under churn (flush scheduling, per-agg latency rng, drop routing).
TWIN_BASE = dict(BASE, n_param_servers=1, consistency="strong",
                 tasks_per_client=3, n_shards=9, max_epochs=1)
CASES.update({
    "tier-flat-twin": (lambda: VCASGD(0.9), dict(TWIN_BASE)),
    "tier-2level": (lambda: VCASGD(0.9), dict(TWIN_BASE, aggregators=1)),
    "tier-fleet": (
        lambda: VCASGD(0.95), dict(FLEET_BASE, aggregators=4), "probe"),
})


def run_case(task, data, name):
    case = CASES[name]
    factory, overrides = case[0], case[1]
    cfg = SimConfig(**{**BASE, **overrides})
    if len(case) > 2 and case[2] == "probe":
        from repro.scenarios.probe import ProbeTask, make_probe_data
        task = ProbeTask()
        data = make_probe_data(cfg.n_shards, seed=cfg.seed)
    res = run_simulation(task, data, factory(), cfg)
    # tier cases also pin the edge/flush accounting; flat cases keep the
    # exact pre-tier fingerprint shape (aggregators == 0 adds nothing)
    extra = {}
    if res.aggregators:
        extra = {
            "aggregators": int(res.aggregators),
            "agg_flushes": int(res.agg_flushes),
            "wire_agg_frames": int(res.wire_agg_frames),
            "edge_wire_frames_sent": int(res.edge_wire.frames_sent),
            "edge_wire_bytes_sent": int(res.edge_wire.bytes_sent),
        }
    return {
        "wall_time_s": float(res.wall_time_s),
        "epochs_done": int(res.epochs_done),
        "final_accuracy": float(res.final_accuracy),
        "results_assimilated": int(res.results_assimilated),
        "reassignments": int(res.reassignments),
        "preemptions": int(res.preemptions),
        "lost_updates": int(res.store_stats.lost_updates),
        "store_updates": int(res.store_stats.updates),
        "acc_mean": [float(p.acc_mean) for p in res.points],
        "t_complete": [float(p.t_complete) for p in res.points],
        "wire_frames_sent": int(res.wire.frames_sent),
        "wire_bytes_sent": int(res.wire.bytes_sent),
        "wire_frames_recv": int(res.wire.frames_recv),
        "wire_bytes_recv": int(res.wire.bytes_recv),
        "wire_frames_dropped": int(res.wire.frames_dropped),
        "wire_bytes_dropped": int(res.wire.bytes_dropped),
        "wire_dense_frames": int(res.wire_dense_frames),
        "wire_sparse_frames": int(res.wire_sparse_frames),
        "wire_handout_frames": int(res.handout_frames),
        "wire_handout_bytes": int(res.handout_bytes),
        "leases_expired": int(res.leases_expired),
        "leases_dropped": int(res.leases_dropped),
        **extra,
    }


def main():
    task = MLPTask()
    data = make_classification_data(n_train=1500, n_val=300, seed=0)
    out = {"base_cfg": BASE, "data": dict(n_train=1500, n_val=300, seed=0),
           "cases": {}}
    for name in CASES:
        out["cases"][name] = run_case(task, data, name)
        print(f"[pin] {name}: acc={out['cases'][name]['final_accuracy']:.4f} "
              f"wall={out['cases'][name]['wall_time_s']:.1f}s "
              f"bytes={out['cases'][name]['wire_bytes_sent']}")
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(out, indent=1) + "\n")
    print(f"[pin] wrote {OUT}")


if __name__ == "__main__":
    main()
